"""MoE dispatch semantics: the scatter/gather capacity dispatch must agree
with the dense-all-experts oracle when capacity is not binding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mlp import (apply_moe_dense_all, apply_moe_dispatch,
                              init_moe)


def _setup(e=4, k=2, b=2, s=16, d=32, ff=64, seed=0, shared=False):
    params = init_moe(jax.random.PRNGKey(seed), d, ff, e,
                      shared_expert=shared)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d),
                          jnp.float32)
    return params, x


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 2)])
def test_dispatch_matches_dense_when_capacity_unbounded(e, k):
    """capacity_factor = E/k => cap = S: no token ever drops, so the
    scatter/gather dispatch equals computing every expert densely."""
    params, x = _setup(e=e, k=k)
    yd, aux_d = apply_moe_dispatch(params, x, e, k, capacity_factor=e / k)
    yo, aux_o = apply_moe_dense_all(params, x, e, k)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yo),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_o), rtol=1e-5)


def test_dispatch_gradients_match_dense():
    e, k = 4, 2
    params, x = _setup(e=e, k=k)

    def loss_d(p):
        y, aux = apply_moe_dispatch(p, x, e, k, capacity_factor=e / k)
        return jnp.sum(jnp.square(y)) + aux

    def loss_o(p):
        y, aux = apply_moe_dense_all(p, x, e, k)
        return jnp.sum(jnp.square(y)) + aux

    gd = jax.grad(loss_d)(params)
    go = jax.grad(loss_o)(params)
    for key in ("router", "w_gate", "w_up", "w_down"):
        np.testing.assert_allclose(np.asarray(gd[key]), np.asarray(go[key]),
                                   rtol=5e-4, atol=5e-5)


def test_capacity_drops_tokens():
    """With a tiny capacity factor, outputs differ from dense (tokens are
    dropped) but remain finite — Switch/GShard semantics."""
    e, k = 4, 1
    params, x = _setup(e=e, k=k, s=32)
    yd, _ = apply_moe_dispatch(params, x, e, k, capacity_factor=0.25)
    yo, _ = apply_moe_dense_all(params, x, e, k)
    assert np.all(np.isfinite(np.asarray(yd)))
    assert not np.allclose(np.asarray(yd), np.asarray(yo), atol=1e-4)


def test_shared_expert_added():
    e, k = 4, 1
    params, x = _setup(e=e, k=k, shared=True)
    y, _ = apply_moe_dispatch(params, x, e, k, capacity_factor=e / k)
    p2 = dict(params)
    p2.pop("shared")
    y2, _ = apply_moe_dispatch(p2, x, e, k, capacity_factor=e / k)
    assert not np.allclose(np.asarray(y), np.asarray(y2))
