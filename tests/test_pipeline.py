"""Hot-path pipeline tests: stacked wire batches, the deferred
telemetry flush, hot-row warm-up, and worker pull-ahead.

The contracts under test:

* **deferred flush bit-identity** — spooling telemetry device-side and
  flushing at eval watermarks must not change a single History row:
  under a pinned schedule the threaded and process backends still agree
  exactly at ``pipeline_depth=0``.
* **hot-row warm-up** — declared ``ClusterConfig.hot_rows`` ranges get
  their ``view_rows`` closures compiled by ``warm``; serving a hot-row
  pull afterwards must not trace anything new (a mid-run retrace is a
  multi-ms stall on the serve hot path).
* **pull-ahead staleness dial** — at ``pipeline_depth=1`` a pinned
  single-worker run records lag 0, 1, 1, ..., 1: exactly +1 designed
  staleness after the first message, on both backends, and the
  sent-snapshot staleness series follows it.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (ClusterConfig, Mailbox, Master, run_cluster)
from repro.core import GammaModel, HyperParams, make_algorithm
from repro.core.metrics import History
from repro.data.synthetic import ClassificationTask
from repro.models.toy import ClassifierGradFn, make_classifier_fns

HP = HyperParams(lr=0.05, momentum=0.9)
TASK = ClassificationTask(dim=8, num_classes=4, batch_size=8, seed=3)
INIT, _, MAKE_EVAL = make_classifier_fns([8, 16, 4])
PARAMS0 = INIT(jax.random.PRNGKey(0))
GRAD_FN = ClassifierGradFn([8, 16, 4])      # picklable: both backends
EVAL_FN = MAKE_EVAL(TASK.eval_batch(32))


def _cfg(backend, *, grads=24, workers=2, **kw):
    return ClusterConfig(num_workers=workers, total_grads=grads,
                         eval_every=8, mode="free",
                         exec_model=GammaModel(seed=5), backend=backend,
                         rpc_timeout=60.0, **kw)


def _run(name, backend, **kw):
    stats = {}
    algo = make_algorithm(name, HP)
    hist = run_cluster(algo, GRAD_FN, PARAMS0, TASK.batch,
                       _cfg(backend, **kw), EVAL_FN, stats_out=stats)
    return hist, stats


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


# ---------------------------------------------------------------------------
# deferred telemetry flush: History rows identical across backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["dana-zero", "dc-asgd"])
def test_deferred_flush_bit_identity(name):
    """Both serve loops now spool telemetry device-side and flush at
    eval watermarks; under the round-robin pin the two backends must
    still produce IDENTICAL schedule telemetry and bit-exact params at
    depth 0 — any reorder, drop, or recompute in the deferred flush
    would break this."""
    ht, st = _run(name, "thread", pin_schedule=True, pipeline_depth=0)
    hp, sp = _run(name, "process", pin_schedule=True, pipeline_depth=0)
    assert hp.worker == ht.worker
    assert hp.lag == ht.lag
    assert hp.step == ht.step
    np.testing.assert_allclose(hp.gap, ht.gap, rtol=1e-6)
    np.testing.assert_allclose(hp.grad_norm, ht.grad_norm, rtol=1e-6)
    # sent-snapshot member: the staleness series rides the same flush
    if name == "dc-asgd":
        assert ht.staleness == [float(l) for l in ht.lag]
        assert hp.staleness == [float(l) for l in hp.lag]
    for a, b in zip(_leaves(ht.final_params), _leaves(hp.final_params)):
        np.testing.assert_array_equal(a, b)
    assert st["applied"] == sp["applied"] == 24


# ---------------------------------------------------------------------------
# hot-row warm-up: no retrace after warm
# ---------------------------------------------------------------------------
def test_hot_row_warm_pins_jit_cache():
    """``Master.warm(hot_ranges=...)`` must compile the declared
    hot-row view closures up front; the first real hot-row pull then
    hits the cache — zero new traces on the serve hot path."""
    algo = make_algorithm("dana-zero", HP)
    master = Master(algo, algo.init(PARAMS0, 4), mailbox=Mailbox(),
                    history=History(), stop=threading.Event(),
                    total_grads=100, coalesce=4, use_kernel=True,
                    record_telemetry=False)
    master.warm(hot_ranges=((0, 8),))
    assert (0, 8) in master._view_rows_jit
    fn = master._view_rows_fn(0, 8)
    assert fn._cache_size() == 1                 # warmed, exactly once
    n_view, n_fused = len(master._view_rows_jit), len(master._fused)
    out = fn(master._flat_state, jnp.int32(1))
    jax.block_until_ready(out)
    assert out.shape[-2] == 8
    assert fn._cache_size() == 1                 # served from cache
    assert len(master._view_rows_jit) == n_view
    assert len(master._fused) == n_fused


def test_hot_row_warm_through_runtime():
    """End-to-end: a threaded run with declared hot_rows completes and
    the hot-row replies still merge correctly (the warm path changed the
    compile schedule, not the protocol)."""
    hist, stats = _run("dana-zero", "thread", workers=2, grads=24,
                       hot_rows=((0, 8), (0, 8)))
    assert stats["applied"] == 24


# ---------------------------------------------------------------------------
# worker pull-ahead
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["thread", "process"])
def test_pullahead_staleness_shift(backend):
    """The designed-staleness dial, measured exactly: one pinned
    worker, coalesce 1.  depth 0 -> every gradient computed on the
    fresh reply (lag 0 everywhere); depth 1 -> gradient i is computed
    on reply i-2's view (lag 1 after the first message): the recorded
    lag series shifts by exactly +1."""
    G = 16
    h0, s0 = _run("dc-asgd", backend, workers=1, grads=G, coalesce=1,
                  pin_schedule=True, pipeline_depth=0)
    h1, s1 = _run("dc-asgd", backend, workers=1, grads=G, coalesce=1,
                  pin_schedule=True, pipeline_depth=1)
    assert h0.lag == [0] * G
    assert h1.lag == [0] + [1] * (G - 1)
    # the sent-snapshot staleness series follows the lag shift (the
    # lane restamps per reply under pull-ahead, so the recorders fall
    # back to lag for the sent family)
    assert h1.staleness == [float(l) for l in h1.lag]
    assert s0["applied"] == s1["applied"] == G


def test_pullahead_free_run_completes_threaded():
    """Multi-worker free-mode pull-ahead: every posted push settles
    (the drain path), every gradient is applied and counted."""
    hist, stats = _run("dana-zero", "thread", workers=3, grads=30,
                       pipeline_depth=1)
    assert stats["applied"] == 30
    assert sum(stats["grads_per_worker"].values()) == 30


def test_pullahead_free_run_completes_process():
    hist, stats = _run("dana-zero", "process", workers=2, grads=24,
                       pipeline_depth=1)
    assert stats["applied"] == 24
    assert sum(stats["grads_per_worker"].values()) == 24


# ---------------------------------------------------------------------------
# shm-ring pull-ahead deadlock freedom
# ---------------------------------------------------------------------------
def _make_shm_ring(rows=8, workers=2, cap=4):
    from multiprocessing import shared_memory

    from repro.cluster.procs import (ShmFanout, ShmLayout, ShmMailbox,
                                     _ShmStop)
    layout = ShmLayout([(0, rows)], num_workers=workers, cap=cap,
                       telemetry=False)
    shm = shared_memory.SharedMemory(create=True, size=layout.total)
    ctl_i = layout.ctl_i(shm.buf)
    ctl_i[:] = 0
    layout.ctl_f(shm.buf)[:] = 0.0
    stop = _ShmStop(ctl_i)
    fanout = ShmFanout(layout, shm.buf, threading.Lock())
    mailbox = ShmMailbox(layout, shm.buf, 0)
    return shm, fanout, mailbox, stop


def _close_shm(shm):
    try:
        shm.close()                 # numpy views may still pin the buffer
    except BufferError:
        pass
    shm.unlink()


def test_rpc_post_settles_own_blocking_token():
    """Ring slots are assigned by a GLOBAL counter, so a worker that
    falls ``cap`` reservations behind reserves a slot whose previous
    occupant is its OWN unsettled pull-ahead token — only its own
    ``rpc_await`` can free it.  ``rpc_post`` must settle the caller's
    ready pending tokens while it spins, instead of self-deadlocking
    (the n=2, depth=1, cap=4 default-config repro)."""
    from collections import deque

    from repro.cluster.mailbox import Reply
    shm, fanout, mailbox, stop = _make_shm_ring()
    try:
        grad = [np.zeros((8, 128), np.float32)]
        view = np.zeros((8, 128), np.float32)

        def serve_all():
            for m in mailbox.drain_nowait():
                m.respond(Reply(view=view, step=1))

        # worker 0 posts idx 0 and leaves it in flight (pull-ahead)
        tok0 = fanout.rpc_post(0, grad, None, 0, 0.0, stop)
        serve_all()
        # worker 1 cycles the rest of the ring: idx 1..3 settled
        for _ in range(3):
            t = fanout.rpc_post(1, grad, None, 0, 0.0, stop)
            serve_all()
            assert fanout.rpc_await(t, 1, stop, 5.0) is not None
        # worker 0's next post reserves idx 4 -> slot 0, blocked on its
        # OWN tok0; the ready-settle path must drain it and proceed
        pending = deque([tok0])
        settled = []
        tok4 = fanout.rpc_post(0, grad, None, 0, 0.0, stop,
                               pending=pending, on_settle=settled.append,
                               rpc_timeout=30.0)
        assert tok4 is not None
        assert not pending
        assert len(settled) == 1 and settled[0] is not None
        serve_all()
        assert fanout.rpc_await(tok4, 0, stop, 5.0) is not None
    finally:
        _close_shm(shm)


def test_rpc_post_times_out_on_wedged_slot():
    """A slot whose occupant genuinely never frees (no server reply, so
    the caller's pending token can't be settled either) must surface as
    TimeoutError from the bounded spin, not an unbounded hang."""
    from collections import deque
    shm, fanout, mailbox, stop = _make_shm_ring()
    try:
        grad = [np.zeros((8, 128), np.float32)]
        toks = [fanout.rpc_post(0, grad, None, 0, 0.0, stop)
                for _ in range(4)]
        with pytest.raises(TimeoutError, match="slot"):
            fanout.rpc_post(0, grad, None, 0, 0.0, stop,
                            pending=deque(toks), on_settle=lambda o: None,
                            rpc_timeout=0.5)
    finally:
        _close_shm(shm)


def test_drain_failure_does_not_mask_loop_error():
    """If ``_live_loop`` dies with in-flight pull-ahead pushes, the
    best-effort settle in ``_run_live`` may itself time out (nobody is
    serving); ``worker.error`` must still record the ORIGINAL loop
    error, not the secondary drain TimeoutError."""
    from repro.cluster.worker import Worker

    class _StubMaster:
        applied, total, step = 0, 100, 0

    boom = RuntimeError("boom")
    calls = {"n": 0}

    def next_batch(wid, counter):
        if calls["n"] >= 1:
            raise boom              # 2nd iteration: one push in flight
        calls["n"] += 1
        return None

    w = Worker(0, master=_StubMaster(), mailbox=Mailbox(),
               grad_jit=lambda v, b: v, next_batch=next_batch,
               stop=threading.Event(), mode="free",
               init_view=(np.zeros((4,), np.float32), 0),
               rpc_timeout=0.2, pipeline_depth=1)
    w.run()
    assert w.error is boom
    assert not w._pending


def test_pullahead_paced_skewed_process_completes():
    """End-to-end version of the reviewer repro: 2 paced workers with
    heterogeneous gamma draws, depth=1, default 4-slot ring — the global
    slot counter repeatedly parks the slow worker behind its own
    in-flight token.  The run must complete, not wedge."""
    stats = {}
    algo = make_algorithm("dana-zero", HP)
    cfg = ClusterConfig(num_workers=2, total_grads=24, eval_every=8,
                        mode="paced", time_scale=1e-4,
                        exec_model=GammaModel(seed=7), backend="process",
                        rpc_timeout=60.0, pipeline_depth=1)
    run_cluster(algo, GRAD_FN, PARAMS0, TASK.batch, cfg, EVAL_FN,
                stats_out=stats)
    assert stats["applied"] == 24
    assert sum(stats["grads_per_worker"].values()) == 24


# ---------------------------------------------------------------------------
# configuration surface
# ---------------------------------------------------------------------------
def test_pipeline_depth_rejects_deterministic():
    algo = make_algorithm("dana-zero", HP)
    cfg = ClusterConfig(num_workers=2, total_grads=8,
                        mode="deterministic",
                        exec_model=GammaModel(seed=5), pipeline_depth=1)
    with pytest.raises(ValueError, match="pipeline_depth"):
        run_cluster(algo, GRAD_FN, PARAMS0, TASK.batch, cfg)


def test_pipeline_depth_rejects_negative():
    algo = make_algorithm("dana-zero", HP)
    cfg = ClusterConfig(num_workers=2, total_grads=8, mode="free",
                        exec_model=GammaModel(seed=5),
                        pipeline_depth=-1)
    with pytest.raises(ValueError, match="pipeline_depth"):
        run_cluster(algo, GRAD_FN, PARAMS0, TASK.batch, cfg)


def test_pipeline_depth_rejects_undersized_shm_ring():
    """The process backend needs (depth+1) slots per worker in the shm
    ring; an explicit mailbox_capacity below that must fail fast, not
    deadlock the ring."""
    algo = make_algorithm("dana-zero", HP)
    cfg = _cfg("process", workers=2, pipeline_depth=1,
               mailbox_capacity=2)
    with pytest.raises(ValueError, match="pipeline_depth"):
        run_cluster(algo, GRAD_FN, PARAMS0, TASK.batch, cfg)
