"""Beyond-paper extensions (paper Sec. 7 future work), demonstrated:

  * DANA-Nadam — the look-ahead transplanted into Nadam's adaptive
    geometry (per-worker first moments + O(k) running sum, sent
    position preconditioned by sqrt(u));
  * DANA-EASGD — the elastic force measured against the PREDICTED
    future center;
  * DANA-Hetero — rate-weighted look-ahead for heterogeneous clusters.

  PYTHONPATH=src python examples/beyond_paper.py
"""
import jax

from repro.core.algorithms import make_algorithm
from repro.core.engine import SimulationConfig, run_simulation
from repro.core.gamma import GammaModel
from repro.core.types import HyperParams
from repro.data.synthetic import ClassificationTask
from repro.models.toy import make_classifier_fns

WORKERS, GRADS = 8, 1200

task = ClassificationTask()
init, grad_fn, make_eval = make_classifier_fns([32, 64, 64, 10])
params0 = init(jax.random.PRNGKey(0))
eval_fn = make_eval(task.eval_batch())

print(f"{'algo':>12} {'env':>6} {'final_loss':>11} {'mean_gap':>9}")
for name, lr, het in [("nadam-asgd", 0.005, False),
                      ("dana-nadam", 0.005, False),
                      ("easgd", 0.02, False),
                      ("dana-easgd", 0.02, False),
                      ("dana-slim", 0.02, True),
                      ("dana-hetero", 0.02, True)]:
    algo = make_algorithm(name, HyperParams(lr=lr, momentum=0.9))
    gm = (GammaModel.heterogeneous_env() if het
          else GammaModel.homogeneous())
    cfg = SimulationConfig(num_workers=WORKERS, total_grads=GRADS,
                           eval_every=300, exec_model=gm)
    h = run_simulation(algo, grad_fn, params0, task.batch, cfg, eval_fn)
    s = h.summary()
    print(f"{name:>12} {'het' if het else 'hom':>6} "
          f"{s['final_loss']:>11.4f} {s['mean_gap']:>9.5f}")

print("\nDANA's look-ahead recipe transfers: per-worker moments + "
      "predicted future position, in any optimizer geometry.")
