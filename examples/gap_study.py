"""Gap study (paper Figure 2b, miniature): why momentum breaks ASGD and
how DANA fixes it.

Runs the same 8-worker schedule under every algorithm and prints the gap
time-series summary — the paper's key diagnostic.

  PYTHONPATH=src python examples/gap_study.py
"""
import numpy as np
import jax

from repro.core.algorithms import make_algorithm
from repro.core.engine import SimulationConfig, run_simulation
from repro.core.types import HyperParams
from repro.data.synthetic import ClassificationTask
from repro.models.toy import make_classifier_fns

ALGOS = ("asgd", "nag-asgd", "lwp", "multi-asgd", "dana-zero", "dana-slim")
WORKERS, GRADS = 8, 1200

task = ClassificationTask()
init, grad_fn, make_eval = make_classifier_fns([32, 64, 64, 10])
params0 = init(jax.random.PRNGKey(0))
eval_fn = make_eval(task.eval_batch())

print(f"{'algo':>11} {'mean_gap':>10} {'norm_gap':>10} {'final_loss':>11}")
rows = {}
for name in ALGOS:
    algo = make_algorithm(name, HyperParams(lr=0.05, momentum=0.9))
    cfg = SimulationConfig(num_workers=WORKERS, total_grads=GRADS,
                           eval_every=300)
    h = run_simulation(algo, grad_fn, params0, task.batch, cfg, eval_fn)
    s = h.summary()
    rows[name] = s
    print(f"{name:>11} {s['mean_gap']:>10.5f} "
          f"{s['mean_normalized_gap']:>10.4f} {s['final_loss']:>11.4f}")

print("\npaper Fig. 2b: gap(dana-zero) ~ gap(asgd) << gap(nag-asgd):",
      f"{rows['dana-zero']['mean_gap']:.5f} ~ {rows['asgd']['mean_gap']:.5f}"
      f" << {rows['nag-asgd']['mean_gap']:.5f}")
