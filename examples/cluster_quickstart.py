"""Cluster runtime quickstart: one algorithm, three execution backends.

1. the discrete-event simulator (the paper's Sec. 5 methodology),
2. the threaded cluster in deterministic mode — same event order,
   bit-for-bit identical parameters (the cross-validation contract),
3. the threaded cluster free-running with coalesced receive and a fault
   plan (a worker drops out and rejoins, messages arrive out of order).

  PYTHONPATH=src python examples/cluster_quickstart.py
"""
import jax
import numpy as np

from repro.cluster import ClusterConfig, FaultPlan, run_cluster
from repro.core import (GammaModel, HyperParams, SimulationConfig,
                        make_algorithm, run_simulation)
from repro.data.synthetic import ClassificationTask
from repro.models.toy import make_classifier_fns


def main():
    task = ClassificationTask(dim=16, num_classes=4, batch_size=16)
    init, grad_fn, make_eval = make_classifier_fns([16, 32, 4])
    params0 = init(jax.random.PRNGKey(0))
    eval_fn = make_eval(task.eval_batch(64))
    hp = HyperParams(lr=0.05, momentum=0.9)
    gm = GammaModel(seed=7)

    # 1. reference: the discrete-event engine -------------------------------
    algo = make_algorithm("dana-zero", hp)
    sim = SimulationConfig(num_workers=4, total_grads=400, eval_every=100,
                           exec_model=gm)
    h_engine = run_simulation(algo, grad_fn, params0, task.batch, sim,
                              eval_fn)
    print(f"engine:          final_loss={h_engine.final_loss():.4f} "
          f"mean_gap={h_engine.mean_gap():.5f}")

    # 2. threaded cluster, deterministic mode -------------------------------
    algo = make_algorithm("dana-zero", hp)
    cfg = ClusterConfig(num_workers=4, total_grads=400, eval_every=100,
                        mode="deterministic", exec_model=gm)
    h_det = run_cluster(algo, grad_fn, params0, task.batch, cfg, eval_fn)
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
               for a, b in zip(jax.tree.leaves(h_engine.final_params),
                               jax.tree.leaves(h_det.final_params)))
    print(f"cluster (det):   final_loss={h_det.final_loss():.4f} "
          f"max param diff vs engine = {diff:.1e}"
          f"{'  (bit-exact)' if diff == 0 else ''}")

    # 3. free-running, coalesced receive + faults ---------------------------
    algo = make_algorithm("dana-zero", hp)
    plan = FaultPlan(seed=1, stall_prob=0.05, stall_scale=4.0,
                     dropout=((3, 100, 250),), reorder_prob=0.25)
    cfg = ClusterConfig(num_workers=8, total_grads=800, eval_every=200,
                        mode="free", coalesce=4, faults=plan)
    stats = {}
    h_live = run_cluster(algo, grad_fn, params0, task.batch, cfg, eval_fn,
                         stats_out=stats)
    print(f"cluster (free):  final_loss={h_live.final_loss():.4f} "
          f"steady={stats['steady_updates_per_s']:.0f} grads/s "
          f"mean_coalesce={stats['mean_coalesce']:.2f} "
          f"kernel={stats['use_kernel']}")
    print(f"  grads per worker (worker 3 dropped out for steps 100-250): "
          f"{stats['grads_per_worker']}")
    print(f"  mean lag={h_live.mean_lag():.2f}  "
          f"mean gap={h_live.mean_gap():.5f}")


if __name__ == "__main__":
    main()
