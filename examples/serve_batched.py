"""Serve a small model with batched requests: prefill + greedy decode.

Demonstrates the serving path the decode dry-run shapes lower — including
a sliding-window cache (the long_500k mechanism) on a dense architecture.

  PYTHONPATH=src python examples/serve_batched.py --arch recurrentgemma-9b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window cache (long-context mechanism)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = jax.tree.map(
        lambda l: l.astype(jnp.bfloat16) if l.dtype == jnp.float32 else l,
        model.init(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    with make_host_mesh():
        toks, stats = generate(model, params, prompts, args.gen,
                               mesh=None, window=args.window)
    print(f"{cfg.name}: {stats}")
    print("generated:", np.asarray(toks).tolist()[0])


if __name__ == "__main__":
    main()
