"""End-to-end driver: asynchronously train a transformer LM with DANA.

The full pipeline — synthetic LM data -> reduced assigned-architecture
model -> DANA-Slim on N simulated asynchronous workers (gamma execution
times) -> gap/lag telemetry -> checkpoint.

Model size is configurable; --dmodel 512 --layers 8 --vocab 8192 gives a
~30M-parameter model, --dmodel 768 --layers 12 --vocab 32k ~110M (slow on
1 CPU core; the default is CI-sized).

  PYTHONPATH=src python examples/train_async_lm.py --workers 4 --grads 200
"""
import argparse
import dataclasses

import jax

from repro.checkpoint.io import save_pytree
from repro.configs import get_config
from repro.core.algorithms import make_algorithm
from repro.core.engine import SimulationConfig, run_simulation
from repro.core.schedules import Schedule
from repro.core.types import HyperParams
from repro.data.synthetic import LMTask
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--algo", default="dana-slim")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--grads", type=int, default=200)
    ap.add_argument("--dmodel", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="results/async_lm.npz")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(
        cfg, d_model=args.dmodel, vocab_size=args.vocab,
        num_heads=max(4, args.dmodel // 64), num_kv_heads=2,
        head_dim=64 if args.dmodel >= 256 else 32,
        d_ff=4 * args.dmodel,
        num_layers=args.layers + len(cfg.pattern_prologue),
        unit_repeats=0)
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    n_params = sum(l.size for l in jax.tree.leaves(params0))
    print(f"model: {cfg.name} {n_params/1e6:.1f}M params, "
          f"algo={args.algo}, workers={args.workers}")

    task = LMTask(vocab_size=args.vocab, seq_len=args.seq,
                  batch_size=args.batch)

    def grad_fn(params, tokens):
        return jax.grad(lambda p: model.loss(p, {"tokens": tokens}))(params)

    ev = task.eval_batch(8)

    def eval_fn(params):
        return model.loss(params, {"tokens": ev})

    sched = Schedule(base_lr=args.lr, num_workers=args.workers,
                     warmup_steps=args.grads // 20,
                     milestones=(int(args.grads * 0.8),))
    algo = make_algorithm(args.algo, HyperParams(lr=args.lr, momentum=0.9),
                          sched)
    cfg_sim = SimulationConfig(num_workers=args.workers,
                               total_grads=args.grads,
                               eval_every=max(args.grads // 10, 1))
    hist = run_simulation(algo, grad_fn, params0,
                          lambda w, c: task.batch(w, c), cfg_sim, eval_fn)
    for t, s, l in zip(hist.eval_time, hist.eval_step, hist.eval_loss):
        print(f"  t={t:9.0f} step={s:5d} eval_loss={l:.4f}")
    print("summary:", {k: round(v, 5) if isinstance(v, float) else v
                       for k, v in hist.summary().items()})
    if args.ckpt:
        save_pytree(args.ckpt, {"params": algo.master_params(
            algo.init(params0, args.workers))})
        print(f"checkpoint -> {args.ckpt}")
    assert hist.eval_loss[-1] < hist.eval_loss[0], "no learning happened?"
    return hist


if __name__ == "__main__":
    main()
