"""Quickstart: DANA in 40 lines.

Trains the same classifier asynchronously on 8 simulated workers with
NAG-ASGD (the naive way to add momentum to ASGD) and DANA-Slim (the
paper's method).  Watch the gap and the final loss.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.algorithms import make_algorithm
from repro.core.engine import SimulationConfig, run_simulation
from repro.core.types import HyperParams
from repro.data.synthetic import ClassificationTask
from repro.models.toy import make_classifier_fns

WORKERS, GRADS = 8, 1500

task = ClassificationTask(dim=32, num_classes=10, batch_size=64)
init, grad_fn, make_eval = make_classifier_fns([32, 64, 64, 10])
params0 = init(jax.random.PRNGKey(0))
eval_fn = make_eval(task.eval_batch())

for name in ("nag-asgd", "dana-slim"):
    algo = make_algorithm(name, HyperParams(lr=0.05, momentum=0.9))
    cfg = SimulationConfig(num_workers=WORKERS, total_grads=GRADS,
                           eval_every=250)
    hist = run_simulation(algo, grad_fn, params0, task.batch, cfg, eval_fn)
    s = hist.summary()
    print(f"{name:>10}: final_loss={s['final_loss']:.4f} "
          f"mean_gap={s['mean_gap']:.5f} mean_lag={s['mean_lag']:.1f}")

print("\nSame lag — but DANA's look-ahead keeps the gap (and loss) small.")
