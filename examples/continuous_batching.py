"""Continuous-batching serving: requests with different lengths arrive,
the engine keeps a fixed slot pool busy (admit -> decode-all -> retire).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve import Engine, Request

cfg = dataclasses.replace(get_config("qwen2-1.5b").reduced(),
                          vocab_size=256)
model = build_model(cfg)
params = jax.tree.map(
    lambda l: l.astype(jnp.bfloat16) if l.dtype == jnp.float32 else l,
    model.init(jax.random.PRNGKey(0)))

engine = Engine(model, params, slots=3, capacity=64,
                prefill_buckets=(16, 32))
rng = np.random.default_rng(0)
for rid in range(7):
    plen = int(rng.integers(6, 28))
    engine.submit(Request(rid=rid, prompt=rng.integers(0, 256, size=plen),
                          max_new=int(rng.integers(4, 10))))

done = engine.run()
for r in sorted(done, key=lambda r: r.rid):
    print(f"req {r.rid}: prompt={len(r.prompt):2d} tok "
          f"-> {len(r.output)} generated {r.output}")
print("\nstats:", {k: round(v, 3) for k, v in engine.stats().items()})
